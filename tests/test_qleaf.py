"""Model-wide qleaf serving (full-model packed coverage).

End-to-end packed-vs-dense **bit-exactness** on CPU for a mixed stack
(attention + MLP + MoE + SSM layers) across ``forward``, ``prefill`` and
``decode_step`` at K ∈ {2, 16}; embedding dequant-on-gather
(``dispatch.quantized_gather``); the non-matrix (MoE expert [E, D, F])
packed layout; the PR-2 MLP-only artifact path (load + serve
bit-exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import (MLP_LEGACY, assert_routes_agree, mixed_cfg as
                     _mixed_cfg, pack_model as _pack, serving_layouts)
from helpers import assert_trees_equal as _tree_equal
from repro.core import CompressionPlan, PackedModel
from repro.core import compression as C
from repro.kernels import dispatch
from repro.models import qleaf as Q
from repro.models.transformer import (decode_step, forward, init_params,
                                      prefill)


# ---------------------------------------------------------------------------
# End-to-end mixed-stack bit-exactness (via the differential harness —
# tests/helpers.py; the K×dtype×mode matrix lives in test_differential.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,tie", [(2, True), (16, False)])
def test_mixed_stack_packed_serving_bit_exact(k, tie):
    cfg = _mixed_cfg(tie)
    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = _pack(params, k)
    layouts = serving_layouts(packed)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)

    # forward / prefill / decode: logits AND caches bit-exact across the
    # dense, uint8-oracle and bit-packed layouts
    assert_routes_agree(cfg, layouts, toks)

    # decode_params collapses the full packed tree back to the dense one
    _tree_equal(dispatch.decode_params(layouts["packed"]), layouts["dense"])


@pytest.mark.parametrize("k", [2, 16])
def test_full_model_leaf_coverage_and_byte_accounting(k):
    """Every 2-D multiplicative leaf serves from the _pidx layout —
    attention q/k/v/o, embedding (and untied head), MoE experts + shared,
    SSM projections — and each packed operand's HBM bytes/weight ==
    bits_per_index(K)/8 (kd padded to lanes)."""
    cfg = _mixed_cfg(tie=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = _pack(params, k)
    sp = packed.serving_params(packed=True)

    assert "embed_tok_pidx" in sp and "head_w_pidx" in sp
    attn_p = sp["stacks"][0]["pos0"]["mixer"]
    for name in ("wq", "wk", "wv", "wo"):
        assert f"{name}_pidx" in attn_p and name not in attn_p
    ssm_p = sp["stacks"][0]["pos1"]["mixer"]
    for name in ("in_z_w", "in_x_w", "in_b_w", "in_c_w", "out_proj_w"):
        assert f"{name}_pidx" in ssm_p and name not in ssm_p
    # excluded-by-policy SSM leaves stay dense (dynamics-sensitive)
    for name in ("dt_w", "a_log", "d_skip", "conv1d_x_w"):
        assert name in ssm_p
    moe_p = sp["stacks"][1]["pos0"]["mlp"]
    for name in ("experts_w_in", "experts_w_gate", "experts_w_out",
                 "shared_w_in", "shared_w_gate", "shared_w_out"):
        assert f"{name}_pidx" in moe_p and name not in moe_p
    assert "router_w" in moe_p                  # router never quantizes
    # non-matrix expert stack: layout records the [E, D, F] dense shape
    lay = moe_p["experts_w_in_layout"]
    assert lay.shape == (4, 48, 24) and lay.kd == 4 * 48 and lay.n == 24

    # the gather-accessed embedding table is row-packed (pack_rows) so the
    # fused gather + transposed-head kernels read bits/8 B/weight; every
    # matmul operand keeps the pack_indices_2d ("kd") orientation.
    assert sp["embed_tok_layout"].order == "row"
    assert sp["head_w_layout"].order == "kd"

    bits = C.bits_per_index(k)
    flat = jax.tree_util.tree_flatten_with_path(sp)[0]
    n_pidx = 0
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        if not ks.endswith("_pidx']"):
            continue
        n_pidx += 1
        layout = _sibling(sp, path, "_layout")
        assert leaf.dtype == jnp.uint32
        assert leaf.shape[-2:] == layout.word_shape
        # measured HBM index bytes/weight == bits_per_index(K)/8 exactly
        # when lanes divide the packed axis; ceil-padded otherwise.
        per_group = int(np.prod(layout.word_shape)) * 4
        packed_axis = layout.kd if layout.order == "kd" else layout.n
        if packed_axis % layout.lanes == 0:
            assert per_group * 8 == bits * layout.kd * layout.n
    assert n_pidx >= 15


def _sibling(tree, path, suffix):
    node = tree
    for entry in path[:-1]:
        node = node[getattr(entry, "key", getattr(entry, "idx", None))]
    name = path[-1].key[:-len("_pidx")]
    return node[name + suffix]


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                  "recurrentgemma-2b"])
def test_mla_and_rglru_packed_serving_bit_exact(arch):
    """The mixer kinds the mixed stack doesn't cover: MLA (absorbed
    decode uses qweight-reshaped w_uk/w_uv) and RG-LRU — packed serving
    stays bit-exact vs dense through prefill + decode."""
    from repro.configs import get_config, reduce_config
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = _pack(params, 16)
    layouts = serving_layouts(packed, which=("dense", "packed"))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    assert_routes_agree(cfg, layouts, toks, modes=("prefill", "decode"),
                        decode_steps=2)


# ---------------------------------------------------------------------------
# Embedding dequant-on-gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 16, 256])
def test_quantized_gather_matches_dense_rows(k):
    """quantized_gather == dense-table row gather, bitwise — including a
    vocab that does not divide the lane count (ragged last word row)."""
    rng = np.random.RandomState(k)
    v, d = 50, 8
    idx = rng.randint(0, k, size=(v, d))
    pidx = jnp.asarray(C.pack_indices_2d(idx, k))
    cb = jnp.asarray(rng.randn(k), jnp.float32)
    layout = C.PackedLayout.make(v, d, k)
    tokens = jnp.asarray([[0, 1, 7, 49, 31], [49, 0, 13, 2, 2]], jnp.int32)
    out = dispatch.quantized_gather(tokens, pidx, cb, layout=layout)
    dense = np.asarray(cb)[idx]
    np.testing.assert_array_equal(np.asarray(out),
                                  dense[np.asarray(tokens)])
    # qleaf qembed: all three layouts agree bitwise
    p_packed = {"emb_pidx": pidx, "emb_cb": cb, "emb_layout": layout}
    p_uint8 = {"emb_idx": jnp.asarray(idx, jnp.uint8), "emb_cb": cb}
    p_dense = {"emb": jnp.asarray(dense)}
    for p in (p_packed, p_uint8, p_dense):
        np.testing.assert_array_equal(
            np.asarray(Q.qembed(p, "emb", tokens)),
            dense[np.asarray(tokens)])


# ---------------------------------------------------------------------------
# PR-2 compatibility: MLP-only layout + deprecated shims
# ---------------------------------------------------------------------------

def test_pr2_mlp_only_artifact_loads_and_serves_bit_exact(tmp_path):
    """The PR-2 artifact path — save → load → MLP-only serving_params —
    still serves bit-exactly through the qleaf-refactored model (the
    deprecated ``mlp_matmul``/``mlp_weight`` aliases are gone; qleaf is
    the only weight-fetch API)."""
    cfg = _mixed_cfg(tie=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = _pack(params, 16)
    packed.save(str(tmp_path))
    loaded = PackedModel.load(str(tmp_path))

    # the PR-2 default coverage: MLP leaves only, everything else dense
    sp = loaded.serving_params(quant_names=MLP_LEGACY, packed=True)
    mlp_p = sp["stacks"][0]["pos0"]["mlp"]
    assert "w_in_pidx" in mlp_p
    # non-MLP leaves decoded dense under the legacy restriction
    assert "wq" in sp["stacks"][0]["pos0"]["mixer"]
    assert "embed_tok" in sp

    dense = loaded.decode()
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab)
    _tree_equal(forward(dense, cfg, toks), forward(sp, cfg, toks))

    # the qleaf entry points answer for the legacy MLP-only layout
    x = jnp.asarray(np.random.RandomState(0).randn(5, cfg.d_model),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(Q.qmatmul(mlp_p, "w_in", x)),
        np.asarray(x @ Q.qweight(mlp_p, "w_in", jnp.float32)))
    assert Q.has_leaf(mlp_p, "w_in")
    assert not Q.has_leaf(mlp_p, "nope")


# ---------------------------------------------------------------------------
# Review regressions: paper nets, bf16 dtype anchoring, coverage honesty
# ---------------------------------------------------------------------------

def test_paper_nets_serve_full_coverage_bit_exact():
    """The paper's own nets read weights through qleaf too: a packed
    artifact with the full-coverage default serves mlp/lenet5 bit-exactly
    (the 'w' leaves rename to w_pidx — previously a KeyError)."""
    from repro.models import paper_nets as PN
    plan = CompressionPlan.parse("adaptive:4")

    params = PN.init_mlp_classifier(jax.random.PRNGKey(0), [32, 16, 8])
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    packed = _pack_with(plan, params, state, qspec)
    sp = packed.serving_params(packed=True)
    assert "w_pidx" in sp["fc0"] and "w" not in sp["fc0"]
    x = jnp.asarray(np.random.RandomState(0).randn(4, 32), jnp.float32)
    _tree_equal(PN.mlp_logits(packed.decode(), x), PN.mlp_logits(sp, x))

    p5 = PN.lenet5_init(jax.random.PRNGKey(2), c1=4, c2=6, fc=32)
    qs5 = plan.build_qspec(p5)
    st5 = plan.init(jax.random.PRNGKey(3), p5, qs5)
    pk5 = _pack_with(plan, p5, st5, qs5)
    x5 = jnp.asarray(np.random.RandomState(1).randn(2, 28, 28, 1),
                     jnp.float32)
    _tree_equal(PN.lenet5_logits(pk5.decode(), x5),
                PN.lenet5_logits(pk5.serving_params(packed=True), x5))


def _pack_with(plan, params, state, qspec):
    return plan.pack(params, state, qspec)


def test_bf16_packed_serving_preserves_leaf_dtype():
    """PackedLayout carries the original leaf dtype: qembed/qweight on a
    bf16 table return bf16 (bitwise equal to the dense decode), so the
    embedding keeps anchoring the residual-stream dtype."""
    plan = CompressionPlan.parse("adaptive:4")
    p = {"embed_tok": jax.random.normal(jax.random.PRNGKey(4), (64, 16)
                                        ).astype(jnp.bfloat16)}
    qspec = plan.build_qspec(p)
    state = plan.init(jax.random.PRNGKey(5), p, qspec)
    packed = plan.pack(p, state, qspec)
    sp = packed.serving_params(packed=True)
    dense = packed.decode()["embed_tok"]
    toks = jnp.asarray([[0, 5, 63]], jnp.int32)
    rows = Q.qembed(sp, "embed_tok", toks)
    assert rows.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(rows, np.float32),
                                  np.asarray(dense[toks], np.float32))
    w = Q.qweight(sp, "embed_tok")
    assert w.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(w, np.float32),
                                  np.asarray(dense, np.float32))
    # uint8 oracle layout: the codebook itself carries the leaf dtype
    up = packed.serving_params(packed=False)
    urows = Q.qembed(up, "embed_tok", toks)
    assert up["embed_tok_cb"].dtype == jnp.bfloat16
    assert urows.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(urows, np.float32),
                                  np.asarray(dense[toks], np.float32))


def test_pre_dskip_fix_artifact_still_serves():
    """An artifact packed with the PR-2-era exclude pattern (which
    quantized the stacked [G, H] ``d_skip`` leaf) must still serve: the
    shared eligibility rule decodes policy-excluded leaves dense even
    when the artifact packed them, since model code reads them raw."""
    import dataclasses as dc
    import re
    from repro.core.plan import QSpecPolicy
    cfg = _mixed_cfg(tie=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    old_exclude = (r"(bias|scale|norm|router|gate_logit|a_log|a_param"
                   r"|dt_|conv1d|embed_pos)")
    plan = dc.replace(CompressionPlan.parse("adaptive:16"),
                      qspec=QSpecPolicy(exclude=old_exclude))
    qspec = plan.build_qspec(params)
    state = plan.init(jax.random.PRNGKey(1), params, qspec)
    packed = plan.pack(params, state, qspec)
    assert any(re.search(r"d_skip", ks) for ks in packed.packed)
    sp = packed.serving_params(packed=True)
    # d_skip decoded dense (raw name present), not renamed to _pidx
    ssm_p = sp["stacks"][0]["pos1"]["mixer"]
    assert "d_skip" in ssm_p and "d_skip_pidx" not in ssm_p
    dense = packed.decode()
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    l0d, _ = prefill(dense, cfg, toks, last_logits_only=True)
    l0p, _ = prefill(sp, cfg, toks, last_logits_only=True)
    _tree_equal(l0d, l0p)
    cov = {r["path"]: r for r in packed.leaf_coverage()}
    (dsk,) = [r for p, r in cov.items() if "d_skip" in p]
    assert not dsk["quantized"] and "policy exclude" in dsk["reason"]


def test_leaf_coverage_matches_serving_eligibility():
    """leaf_coverage must report what serving_params actually executes:
    K > 256 leaves decode dense and are not counted as quantized."""
    plan = CompressionPlan.parse("adaptive:512")
    p = {"fc": {"w": jax.random.normal(jax.random.PRNGKey(6), (16, 8))}}
    qspec = plan.build_qspec(p)
    state = plan.init(jax.random.PRNGKey(7), p, qspec)
    packed = plan.pack(p, state, qspec)
    (row,) = [r for r in packed.leaf_coverage() if r["k"]]
    assert not row["quantized"] and "256" in row["reason"]
    sp = packed.serving_params(packed=True)
    assert "w" in sp["fc"] and "w_pidx" not in sp["fc"]


# ---------------------------------------------------------------------------
# qleaf unit behaviour
# ---------------------------------------------------------------------------

def test_qweight_reshapes_non_matrix_packed_leaf():
    """A [E, D, F] expert stack round-trips through the packed (E·D, F)
    word layout back to its dense shape, bitwise."""
    rng = np.random.RandomState(7)
    e, d, f, k = 3, 8, 5, 4
    idx = rng.randint(0, k, size=(e, d, f))
    cb = jnp.asarray(rng.randn(k), jnp.float32)
    pidx = jnp.asarray(C.pack_indices_2d(idx.reshape(e * d, f), k))
    layout = C.PackedLayout.make(e * d, f, k, shape=(e, d, f))
    p = {"w_pidx": pidx, "w_cb": cb, "w_layout": layout}
    w = Q.qweight(p, "w")
    assert w.shape == (e, d, f)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(cb)[idx])
    # qmatmul on a non-matrix layout takes the dequant-then-dot route:
    # x contracts against the flattened (E·D, F) view's last matrix only
    # when shapes align — here we just pin the decode path equivalence.
    x = jnp.asarray(rng.randn(2, d), jnp.float32)
    y = jnp.einsum("bd,edf->ebf", x, w)
    y2 = jnp.einsum("bd,edf->ebf", x, jnp.asarray(np.asarray(cb)[idx]))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


def test_qmatmul_ref_route_is_dense_graph():
    """On the ref backend (CPU default) qmatmul is literally x @ cb[idx]
    — bitwise equal to the dense contraction, for both layouts and for
    3-D (batched) activations."""
    rng = np.random.RandomState(1)
    kd, n, k = 32, 12, 16
    idx = rng.randint(0, k, size=(kd, n))
    cb = jnp.asarray(rng.randn(k), jnp.float32)
    w = jnp.asarray(np.asarray(cb)[idx])
    pidx = jnp.asarray(C.pack_indices_2d(idx, k))
    layout = C.PackedLayout.make(kd, n, k)
    x = jnp.asarray(rng.randn(2, 3, kd), jnp.float32)
    want = np.asarray(x @ w)
    p_packed = {"w_pidx": pidx, "w_cb": cb, "w_layout": layout}
    p_uint8 = {"w_idx": jnp.asarray(idx, jnp.uint8), "w_cb": cb}
    np.testing.assert_array_equal(
        np.asarray(Q.qmatmul(p_packed, "w", x)), want)
    np.testing.assert_array_equal(
        np.asarray(Q.qmatmul(p_uint8, "w", x)), want)
    # transposed (tied-embedding head) route
    xt = jnp.asarray(rng.randn(4, n), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(Q.qmatmul_t(p_packed, "w", xt)), np.asarray(xt @ w.T))
