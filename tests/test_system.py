"""End-to-end behaviour tests for the paper's system.

The headline claims, at test scale:
  1. full LC pipeline quantizes a trained classifier to K=2 (1 bit/weight)
     with small loss degradation, and strictly beats DC there;
  2. LC with the serving path: finalize → pack → codebook-matmul kernel
     reproduces the quantized net's logits exactly;
  3. the LC trainer integrates with the LM stack (tiny transformer).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LCConfig, baselines, compression, default_qspec,
                        feasibility_gap, make_scheme, param_counts)
from repro.data.synthetic import mnist_like
from repro.kernels import ops as kops
from repro.models.paper_nets import (classification_error, cross_entropy,
                                     init_mlp_classifier, mlp_logits)
from repro.train.trainer import (LCTrainer, TrainerConfig, init_train_state,
                                 make_train_step)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def trained_reference():
    # capacity-tight net (H=8): loss-blind quantization (DC) measurably
    # hurts, which is the paper's K=2 regime (overparameterized nets make
    # any K=2 codebook "good enough" and hide the LC-vs-DC separation)
    X, Y = mnist_like(0, 4096, noise=1.0)
    params = init_mlp_classifier(KEY, [784, 8, 10])

    def loss_fn(p, batch):
        return cross_entropy(mlp_logits(p, batch[0]), batch[1])

    def batches():
        i = 0
        while True:
            k = jax.random.fold_in(jax.random.PRNGKey(1), i)
            idx = jax.random.randint(k, (256,), 0, X.shape[0])
            yield (X[idx], Y[idx])
            i += 1

    tc = TrainerConfig(lr=0.1, steps_per_l=50)
    state = init_train_state(params, tc)
    step = jax.jit(make_train_step(loss_fn, tc))
    it = batches()
    for _ in range(400):
        state, m = step(state, next(it))
    return X, Y, state.params, loss_fn, batches


def test_lc_binarizes_with_small_degradation(trained_reference):
    X, Y, ref_params, loss_fn, batches = trained_reference
    ref_loss = float(loss_fn(ref_params, (X, Y)))

    qspec = default_qspec(ref_params)
    scheme = make_scheme("adaptive:2")
    lc_cfg = LCConfig(mu0=1e-3, mu_growth=1.25, num_lc_iters=30)
    tr = LCTrainer(loss_fn, scheme, qspec, lc_cfg,
                   TrainerConfig(lr=0.1, steps_per_l=40))
    state = tr.init(KEY, ref_params)
    state = tr.run(state, batches())
    q_params = tr.finalize(state)

    # feasible: each layer ≤ 2 distinct values (784-32-10 MLP: fc0, fc1)
    for layer in ["fc0", "fc1"]:
        assert len(np.unique(np.asarray(q_params[layer]["w"]))) <= 2
    lc_loss = float(loss_fn(q_params, (X, Y)))

    dc_params, _ = baselines.direct_compression(KEY, ref_params, scheme, qspec)
    dc_loss = float(loss_fn(dc_params, (X, Y)))
    # paper fig. 9 @ K=2: LC ≪ DC
    assert lc_loss < dc_loss
    err_ref = float(classification_error(mlp_logits(ref_params, X), Y))
    err_lc = float(classification_error(mlp_logits(q_params, X), Y))
    assert err_lc <= err_ref + 0.05      # ≤5 pts degradation at 1 bit/weight

    p1, p0 = param_counts(ref_params, qspec)
    rho = compression.compression_ratio(p1, p0, 2, 3 * 2)
    assert rho > 25          # ~×30 with b=32 (paper eq. 14 regime)


def test_packed_serving_path_exact(trained_reference):
    """finalize → assignments → bit-pack → unpack → codebook-matmul kernel
    equals the quantized net's dense forward, bit-exactly in f32."""
    X, Y, ref_params, loss_fn, batches = trained_reference
    qspec = default_qspec(ref_params)
    scheme = make_scheme("adaptive:4")
    lc_cfg = LCConfig(mu0=1e-3, mu_growth=1.4, num_lc_iters=12)
    tr = LCTrainer(loss_fn, scheme, qspec, lc_cfg,
                   TrainerConfig(lr=0.05, steps_per_l=20))
    state = tr.init(KEY, ref_params)
    state = tr.run(state, batches())
    q_params = tr.finalize(state)

    th = state.lc_state.theta["['fc0']['w']"]
    cb = np.asarray(th["codebook"])
    w_q = np.asarray(q_params["fc0"]["w"])
    assign = np.argmin((w_q[..., None] - cb) ** 2, axis=-1)
    words, lanes = compression.pack_indices(assign, cb.shape[0])
    idx = compression.unpack_indices(jnp.asarray(words), assign.size,
                                     cb.shape[0]).reshape(assign.shape)
    x = X[:64]
    y_kernel = kops.codebook_matmul(x, idx.astype(jnp.uint8),
                                    jnp.asarray(cb), bm=32, bn=32, bk=128)
    y_dense = x @ q_params["fc0"]["w"]
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-4)


def test_lc_trainer_on_tiny_lm():
    """LC quantization plugged into the transformer stack end to end."""
    from repro.configs import get_config, reduce_config
    from repro.data.synthetic import lm_batch
    from repro.models.transformer import init_params, loss_fn as lm_loss

    cfg = reduce_config(get_config("qwen1.5-0.5b"))
    params = init_params(KEY, cfg)

    def loss(p, batch):
        return lm_loss(p, cfg, batch)

    def batches():
        i = 0
        while True:
            yield lm_batch(0, i, 4, 32, cfg.vocab)
            i += 1

    qspec = default_qspec(params)
    scheme = make_scheme("adaptive:4")
    tr = LCTrainer(loss, scheme, qspec,
                   LCConfig(mu0=1e-2, mu_growth=1.6, num_lc_iters=6),
                   TrainerConfig(lr=0.05, steps_per_l=8))
    state = tr.init(KEY, params)
    state = tr.run(state, batches())
    gap = float(feasibility_gap(state.params, state.lc_state, qspec))
    q = tr.finalize(state)
    # stacked leaves: per-layer codebooks → ≤ 4 values per group slice
    wq = np.asarray(q["stacks"][0]["pos0"]["mlp"]["w_in"])
    for g in range(wq.shape[0]):
        assert len(np.unique(wq[g])) <= 4
    l = float(loss(q, lm_batch(0, 999, 4, 32, cfg.vocab)))
    assert np.isfinite(l)
